"""PEFT methods x quant modes: adapters are the only trainable params, every
mode trains, prompt methods extend the sequence correctly."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader
from repro.models import model as M
from repro.models.config import ModelConfig, QuantConfig, TrainConfig
from repro.train import steps as S


def _cfg(peft="lora", mode="quaff"):
    return ModelConfig(
        name="pb-test", family="dense", n_layers=2, d_model=48, n_heads=4,
        n_kv_heads=2, d_ff=96, vocab_size=64, head_dim=12,
        quant=QuantConfig(mode=mode),
        peft=PEFTConfig(method=peft, lora_rank=2, n_virtual_tokens=4))


@pytest.mark.parametrize("peft", ["lora", "ia3", "prompt", "ptuning"])
def test_peft_methods_train(peft):
    cfg = _cfg(peft=peft)
    tcfg = TrainConfig(microbatches=1, remat=False, learning_rate=5e-3)
    frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
    state = S.init_train_state(adapters, qstate, tcfg)
    step = jax.jit(S.build_train_step(cfg, tcfg))
    loader = Loader(DataConfig(vocab_size=64, seq_len=16, batch_size=4))
    frozen_before = jax.tree.map(lambda x: np.asarray(x).copy(), frozen)
    for i in range(3):
        state, metrics = step(frozen, state, jax.tree.map(
            jnp.asarray, loader.batch(i)))
        assert bool(jnp.isfinite(metrics["loss"])), peft
    # adapters moved, frozen untouched
    moved = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(adapters),
                                jax.tree.leaves(state.adapters)))
    assert moved > 0, f"{peft}: adapters frozen?"
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), b), frozen, frozen_before)


@pytest.mark.parametrize("peft", ["prompt", "ptuning"])
def test_prompt_extends_sequence(peft):
    cfg = _cfg(peft=peft)
    frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
    tok = jnp.zeros((2, 8), jnp.int32)
    out = M.forward(frozen, adapters, qstate, tok, cfg)
    assert out.logits.shape[1] == 8 + cfg.peft.n_virtual_tokens


def test_lora_dropout_train_vs_eval():
    """Train (rng passed) and eval (no rng) logits differ exactly when
    lora_dropout > 0 — the PEFTConfig.lora_dropout knob is live."""
    import dataclasses

    cfg = _cfg(peft="lora")  # PEFTConfig default lora_dropout = 0.1
    assert cfg.peft.lora_dropout > 0.0
    frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
    # LoRA inits with B = 0 (adapter is a no-op); randomize so it contributes
    rng = np.random.RandomState(0)
    adapters = jax.tree.map(
        lambda a: jnp.asarray(rng.normal(0, 0.1, a.shape), a.dtype), adapters)
    tok = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 16)))

    ev = M.forward(frozen, adapters, qstate, tok, cfg)               # eval
    tr = M.forward(frozen, adapters, qstate, tok, cfg,
                   rng=jax.random.PRNGKey(3))                        # train
    assert not np.allclose(np.asarray(ev.logits), np.asarray(tr.logits)), \
        "dropout > 0 with an rng must perturb the train-path logits"

    cfg0 = dataclasses.replace(cfg, peft=dataclasses.replace(
        cfg.peft, lora_dropout=0.0))
    tr0 = M.forward(frozen, adapters, qstate, tok, cfg0,
                    rng=jax.random.PRNGKey(3))
    np.testing.assert_allclose(np.asarray(ev.logits), np.asarray(tr0.logits),
                               rtol=1e-6, atol=1e-6)

    # same rng twice -> identical (the stochasticity is fully keyed)
    tr2 = M.forward(frozen, adapters, qstate, tok, cfg,
                    rng=jax.random.PRNGKey(3))
    np.testing.assert_allclose(np.asarray(tr.logits), np.asarray(tr2.logits))


def test_train_step_dropout_flag():
    """TrainConfig.deterministic=False turns LoRA dropout on inside the
    jitted train step; the default stays deterministic."""
    cfg = _cfg(peft="lora")
    loader = Loader(DataConfig(vocab_size=64, seq_len=16, batch_size=4))
    batch = jax.tree.map(jnp.asarray, loader.batch(0))

    def one_step(deterministic, seed):
        tcfg = TrainConfig(microbatches=1, remat=False,
                           deterministic=deterministic, seed=seed)
        frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
        # non-zero LoRA B so dropout has something to act on
        rng = np.random.RandomState(1)
        adapters = jax.tree.map(
            lambda a: jnp.asarray(rng.normal(0, 0.1, a.shape), a.dtype),
            adapters)
        state = S.init_train_state(adapters, qstate, tcfg)
        step = jax.jit(S.build_train_step(cfg, tcfg))
        state, metrics = step(frozen, state, batch)
        return float(metrics["loss"])

    det = one_step(True, 0)
    sto = one_step(False, 0)
    assert det != sto, "dropout should change the train loss"
    # keyed from (seed, step): same seed reproduces exactly
    assert sto == one_step(False, 0)
    assert sto != one_step(False, 7)


@pytest.mark.parametrize("mode", ["fp32", "naive", "llm_int8",
                                  "smooth_static", "smooth_dynamic", "quaff"])
def test_all_quant_modes_train(mode):
    cfg = _cfg(mode=mode)
    tcfg = TrainConfig(microbatches=1, remat=False)
    frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
    state = S.init_train_state(adapters, qstate, tcfg)
    step = jax.jit(S.build_train_step(cfg, tcfg))
    loader = Loader(DataConfig(vocab_size=64, seq_len=16, batch_size=4))
    state, metrics = step(frozen, state, jax.tree.map(jnp.asarray,
                                                      loader.batch(0)))
    assert bool(jnp.isfinite(metrics["loss"])), mode


def test_quant_modes_close_to_fp32():
    """Forward logits of every quant mode stay near the fp32 model."""
    import repro.train.calibrate as C
    from repro.data.pipeline import calibration_batches

    cfg = _cfg(mode="fp32")
    frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
    batches = calibration_batches(
        DataConfig(vocab_size=64, seq_len=16, batch_size=4), 2)
    stats = C.capture_stats(frozen, adapters, qstate, cfg, batches)
    tok = jnp.asarray(batches[0]["tokens"])
    ref, _, _, _ = M.forward(frozen, adapters, qstate, tok, cfg)
    scale = float(jnp.mean(jnp.abs(ref)))
    for mode in ("naive", "smooth_static", "quaff"):
        fz, qs = C.convert(frozen, stats, cfg, mode)
        cfg_m = dataclasses.replace(
            cfg, quant=dataclasses.replace(cfg.quant, mode=mode))
        got, _, _, _ = M.forward(fz, adapters, qs, tok, cfg_m)
        rel = float(jnp.mean(jnp.abs(got - ref))) / scale
        assert rel < 0.15, (mode, rel)
