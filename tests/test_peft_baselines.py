"""PEFT methods x quant modes: adapters are the only trainable params, every
mode trains, prompt methods extend the sequence correctly."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader
from repro.models import model as M
from repro.models.config import ModelConfig, QuantConfig, TrainConfig
from repro.train import steps as S


def _cfg(peft="lora", mode="quaff"):
    return ModelConfig(
        name="pb-test", family="dense", n_layers=2, d_model=48, n_heads=4,
        n_kv_heads=2, d_ff=96, vocab_size=64, head_dim=12,
        quant=QuantConfig(mode=mode),
        peft=PEFTConfig(method=peft, lora_rank=2, n_virtual_tokens=4))


@pytest.mark.parametrize("peft", ["lora", "ia3", "prompt", "ptuning"])
def test_peft_methods_train(peft):
    cfg = _cfg(peft=peft)
    tcfg = TrainConfig(microbatches=1, remat=False, learning_rate=5e-3)
    frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
    state = S.init_train_state(adapters, qstate, tcfg)
    step = jax.jit(S.build_train_step(cfg, tcfg))
    loader = Loader(DataConfig(vocab_size=64, seq_len=16, batch_size=4))
    frozen_before = jax.tree.map(lambda x: np.asarray(x).copy(), frozen)
    for i in range(3):
        state, metrics = step(frozen, state, jax.tree.map(
            jnp.asarray, loader.batch(i)))
        assert bool(jnp.isfinite(metrics["loss"])), peft
    # adapters moved, frozen untouched
    moved = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(adapters),
                                jax.tree.leaves(state.adapters)))
    assert moved > 0, f"{peft}: adapters frozen?"
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), b), frozen, frozen_before)


@pytest.mark.parametrize("peft", ["prompt", "ptuning"])
def test_prompt_extends_sequence(peft):
    cfg = _cfg(peft=peft)
    frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
    tok = jnp.zeros((2, 8), jnp.int32)
    logits, _, _, _ = M.forward(frozen, adapters, qstate, tok, cfg)
    assert logits.shape[1] == 8 + cfg.peft.n_virtual_tokens


@pytest.mark.parametrize("mode", ["fp32", "naive", "llm_int8",
                                  "smooth_static", "smooth_dynamic", "quaff"])
def test_all_quant_modes_train(mode):
    cfg = _cfg(mode=mode)
    tcfg = TrainConfig(microbatches=1, remat=False)
    frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
    state = S.init_train_state(adapters, qstate, tcfg)
    step = jax.jit(S.build_train_step(cfg, tcfg))
    loader = Loader(DataConfig(vocab_size=64, seq_len=16, batch_size=4))
    state, metrics = step(frozen, state, jax.tree.map(jnp.asarray,
                                                      loader.batch(0)))
    assert bool(jnp.isfinite(metrics["loss"])), mode


def test_quant_modes_close_to_fp32():
    """Forward logits of every quant mode stay near the fp32 model."""
    import repro.train.calibrate as C
    from repro.data.pipeline import calibration_batches

    cfg = _cfg(mode="fp32")
    frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
    batches = calibration_batches(
        DataConfig(vocab_size=64, seq_len=16, batch_size=4), 2)
    stats = C.capture_stats(frozen, adapters, qstate, cfg, batches)
    tok = jnp.asarray(batches[0]["tokens"])
    ref, _, _, _ = M.forward(frozen, adapters, qstate, tok, cfg)
    scale = float(jnp.mean(jnp.abs(ref)))
    for mode in ("naive", "smooth_static", "quaff"):
        fz, qs = C.convert(frozen, stats, cfg, mode)
        cfg_m = dataclasses.replace(
            cfg, quant=dataclasses.replace(cfg.quant, mode=mode))
        got, _, _, _ = M.forward(fz, adapters, qs, tok, cfg_m)
        rel = float(jnp.mean(jnp.abs(got - ref))) / scale
        assert rel < 0.15, (mode, rel)
