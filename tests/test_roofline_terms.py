"""HLO roofline-term extraction correctness: hand-computable sharded
programs in a subprocess (forced multi-device), asserting flops / collective
bytes / trip-count handling against analytic values."""
import subprocess
import sys

import conftest

from repro.launch import hloparse

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch import hloparse

mesh = jax.make_mesh((2, 4), ("data", "model"), devices=jax.devices()[:8],
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)

# 1) sharded fp32 matmul: per-device flops = global/8 when fully sharded
def f(x, w):
    y = x @ w
    return jax.lax.with_sharding_constraint(y, P("data", "model"))
with jax.set_mesh(mesh):
    c = jax.jit(f, in_shardings=(NamedSharding(mesh, P("data", None)),
                                 NamedSharding(mesh, P(None, "model")))
                ).lower(jax.ShapeDtypeStruct((256, 512), jnp.float32),
                        jax.ShapeDtypeStruct((512, 1024), jnp.float32)
                ).compile()
s = hloparse.analyze(c.as_text())
expect = 2 * 256 * 512 * 1024 / 8
assert abs(s.dot_flops_float - expect) / expect < 0.01, (s.dot_flops_float, expect)

# 2) scan trip count: 5 iterations of an int8 matmul
def g(x, ws):
    def body(cacc, w):
        y = jax.lax.dot_general(cacc, w, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.int32)
        return jnp.clip(y, -127, 127).astype(jnp.int8), None
    out, _ = jax.lax.scan(body, x, ws)
    return out
c2 = jax.jit(g).lower(jax.ShapeDtypeStruct((64, 128), jnp.int8),
                      jax.ShapeDtypeStruct((5, 128, 128), jnp.int8)).compile()
s2 = hloparse.analyze(c2.as_text())
expect2 = 5 * 2 * 64 * 128 * 128
assert abs(s2.dot_flops_int8 - expect2) / expect2 < 0.01, (s2.dot_flops_int8, expect2)
assert s2.dot_flops_float == 0.0

# 3) collective bytes: explicit psum over "data" of a known-size array
def h(x):
    def inner(v):
        return jax.lax.psum(v, "data")
    return jax.shard_map(inner, mesh=mesh, in_specs=P(None, None),
                         out_specs=P(None, None))(x)
with jax.set_mesh(mesh):
    c3 = jax.jit(h).lower(jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
s3 = hloparse.analyze(c3.as_text())
ar = s3.collective_bytes.get("all-reduce", 0)
assert ar >= 128 * 128 * 4, s3.collective_bytes
print("OK")
"""


@conftest.requires_modern_jax
def test_roofline_extraction_subprocess():
    r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, (r.stderr or r.stdout)[-3000:]
    assert "OK" in r.stdout


def test_type_bytes():
    assert hloparse._type_bytes("f32[8,4]{1,0}") == 128
    assert hloparse._type_bytes("bf16[2,3]{1,0}") == 12
    assert hloparse._type_bytes("s8[100]{0}") == 100
    assert hloparse._type_bytes("(f32[4]{0}, s32[2]{0})") == 24
    assert hloparse._type_bytes("pred[]") == 1


def test_parse_op_line():
    op = hloparse._parse_op_line(
        "  %dot.3 = f32[128,64]{1,0} dot(%a, %b), lhs_contracting_dims={1}, "
        "rhs_contracting_dims={0}")
    assert op.opcode == "dot" and op.name == "dot.3"
    op2 = hloparse._parse_op_line(
        "  ROOT %t = (f32[2]{0}, s32[]) tuple(%x, %y)")
    assert op2.opcode == "tuple"
