"""Per-assigned-architecture smoke tests: instantiate a REDUCED config of
the same family, run one forward and one train step on CPU, assert output
shapes and no NaNs. The FULL configs are only exercised via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.core.peft import PEFTConfig
from repro.models import model as M
from repro.models.config import QuantConfig, TrainConfig
from repro.train import steps as S

pytestmark = pytest.mark.slow  # full-zoo smoke: minutes of compiles

BATCH, SEQ = 2, 32


def _reduced(arch: str):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg,
        quant=QuantConfig(mode="quaff"),
        peft=PEFTConfig(method="lora", lora_rank=4),
    )
    return cfg


def _batch(cfg, key=0):
    rng = np.random.RandomState(key)
    n_text = SEQ - (cfg.n_image_tokens if cfg.family == "vlm" else 0)
    out = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (BATCH, n_text))),
        "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (BATCH, SEQ))),
    }
    if cfg.family == "vlm":
        out["embeds"] = jnp.asarray(
            rng.randn(BATCH, cfg.n_image_tokens, cfg.d_model).astype(np.float32))
        out["labels"] = out["labels"][:, :n_text]  # labels align to text positions
    if cfg.family == "encdec":
        out["embeds"] = jnp.asarray(
            rng.randn(BATCH, cfg.encoder_seq, cfg.d_model).astype(np.float32))
        out["labels"] = out["labels"][:, :n_text]
    return out


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_smoke(arch):
    cfg = _reduced(arch)
    frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, stats, _, aux = M.forward(
        frozen, adapters, qstate, batch["tokens"], cfg,
        input_embeds=batch.get("embeds"))
    exp_seq = SEQ if cfg.family != "encdec" else batch["tokens"].shape[1]
    assert logits.shape == (BATCH, exp_seq, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"NaN/Inf logits for {arch}"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch):
    cfg = _reduced(arch)
    tcfg = TrainConfig(microbatches=2, remat=True)
    frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
    state = S.init_train_state(adapters, qstate, tcfg)
    step = jax.jit(S.build_train_step(cfg, tcfg))
    batch = _batch(cfg)
    new_state, metrics = step(frozen, state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"non-finite loss for {arch}"
    assert int(new_state.step) == 1
    # adapters actually moved
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(a - b))),
                     state.adapters, new_state.adapters))
    assert delta > 0, f"adapters did not update for {arch}"


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "zamba2-1.2b",
                                  "xlstm-350m", "whisper-large-v3",
                                  "olmoe-1b-7b"])
def test_decode_smoke(arch):
    """One prefill + one decode step; logits finite, cache pos advances."""
    cfg = _reduced(arch)
    frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    prefill = S.build_prefill(cfg, extra_len=4)
    decode = S.build_decode(cfg)
    logits, caches = prefill(frozen, adapters, qstate, batch)
    assert logits.shape == (BATCH, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    pos = jnp.asarray(batch["tokens"].shape[1], jnp.int32)
    logits2, caches2 = decode(frozen, adapters, qstate, caches, tok, pos)
    assert logits2.shape == (BATCH, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))
