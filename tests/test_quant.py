"""Quantization-primitive invariants. Property tests run under hypothesis
when it is installed; a deterministic fixed-case sweep exercises the same
invariants either way, so the file never aborts collection on a missing
optional dependency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: deterministic fallbacks below still run
    given = None

if given is not None:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")


# --------------------------------------------------------------------------
# Deterministic invariant checks (always collected; the hypothesis section
# below widens the same properties over random inputs when available).
# --------------------------------------------------------------------------
_FIXED_CASES = [((2, 2), 0, 0.1), ((7, 3), 1, 1.0), ((16, 64), 2, 10.0),
                ((64, 5), 3, 100.0)]


@pytest.mark.parametrize("shape,seed,scale", _FIXED_CASES)
def test_roundtrip_error_bound_fixed(shape, seed, scale):
    """|x - dequant(quant(x))| <= delta/2 elementwise, every granularity."""
    x = jax.random.normal(jax.random.PRNGKey(seed), shape) * scale
    for axis in (None, -1, 0):
        x_int, delta = quant.quantize(x, axis=axis)
        err = jnp.abs(x - quant.dequantize(x_int, delta))
        bound = jnp.broadcast_to(delta, x.shape) * 0.5 + 1e-6
        assert bool(jnp.all(err <= bound)), (axis, float(jnp.max(err - bound)))


@pytest.mark.parametrize("shape,seed", [(s, i) for (s, i, _) in _FIXED_CASES])
def test_int8_range_fixed(shape, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape) * 50
    x_int, _ = quant.quantize(x, axis=-1)
    assert x_int.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(x_int.astype(jnp.int32)))) <= 127


def test_delta_positive():
    x = jnp.zeros((4, 8))
    delta = quant.compute_delta(x, axis=-1)
    assert bool(jnp.all(delta > 0))


def test_granularity_shapes():
    x = jnp.ones((6, 10))
    _, d_tensor = quant.quantize(x, axis=None)
    _, d_token = quant.quantize(x, axis=-1)
    _, d_oc = quant.quantize(x, axis=0)
    assert d_tensor.shape == ()
    assert d_token.shape == (6, 1)
    assert d_oc.shape == (1, 10)


def test_int_matmul_exact():
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.randint(ka, (16, 32), -127, 128, jnp.int8)
    b = jax.random.randint(kb, (32, 8), -127, 128, jnp.int8)
    got = quant.int_matmul(a, b)
    want = np.asarray(a, np.int64) @ np.asarray(b, np.int64)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)


def test_quantized_matmul_error_and_grad():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(k1, (32, 64))
    w = jax.random.normal(k2, (64, 16)) * 0.1
    w_int, w_delta = quant.quantize(w, axis=0)
    y = quant.quantized_matmul(x, w_int, w_delta)
    rel = float(jnp.mean(jnp.abs(y - x @ w)) / jnp.mean(jnp.abs(x @ w)))
    assert rel < 0.05
    for bwd_int8 in (True, False):
        g = jax.grad(lambda xx: quant.quantized_matmul(
            xx, w_int, w_delta, 8, bwd_int8).sum())(x)
        g_ref = jax.grad(lambda xx: (xx @ w).sum())(x)
        grel = float(jnp.mean(jnp.abs(g - g_ref)) / jnp.mean(jnp.abs(g_ref)))
        assert grel < 0.05, (bwd_int8, grel)


def test_fake_quant_ste():
    x = jnp.linspace(-2, 2, 32).reshape(4, 8)
    y = quant.fake_quant(x, None)
    assert y.shape == x.shape
    g = jax.grad(lambda v: quant.fake_quant(v, None).sum())(x)
    np.testing.assert_allclose(np.asarray(g), np.ones_like(g))  # STE identity


def test_int4_quantization():
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 8))
    x_int, delta = quant.quantize(x, axis=-1, bits=4)
    assert int(jnp.max(jnp.abs(x_int.astype(jnp.int32)))) <= 7


# --------------------------------------------------------------------------
# Hypothesis property tests (skipped cleanly when hypothesis is absent)
# --------------------------------------------------------------------------
if given is not None:

    def _arrays(min_dim=2, max_dim=64):
        return st.integers(min_dim, max_dim).flatmap(
            lambda n: st.integers(min_dim, max_dim).map(lambda m: (n, m)))

    @given(_arrays(), st.integers(0, 2 ** 31 - 1), st.floats(0.1, 100.0))
    def test_roundtrip_error_bound(shape, seed, scale):
        test_roundtrip_error_bound_fixed(shape, seed, scale)

    @given(_arrays(), st.integers(0, 2 ** 31 - 1))
    def test_int8_range(shape, seed):
        test_int8_range_fixed(shape, seed)
