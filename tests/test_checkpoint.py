"""Checkpoint manager: roundtrip, atomicity, retention, crash-resume
equivalence (the fault-tolerance contract), and the facade-level
``save``/``load`` lifecycle (config fingerprint + bit-identical eval)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.checkpoint.manager import CheckpointManager, config_fingerprint
from repro.core.peft import PEFTConfig
from repro.data.pipeline import DataConfig, Loader, calibration_batches
from repro.models import model as M
from repro.models.config import ModelConfig, QuantConfig, TrainConfig
from repro.train import steps as S


def _tiny_cfg():
    return ModelConfig(
        name="ckpt-test", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8,
        quant=QuantConfig(mode="quaff"),
        peft=PEFTConfig(method="lora", lora_rank=2))


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(3, tree, {"note": "x"})
    got, meta = mgr.restore(tree)
    assert meta["step"] == 3 and meta["note"] == "x"
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, got)


def test_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = {"x": jnp.zeros(())}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_tmp_dirs_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert mgr.latest_step() is None  # half-written ckpt never published


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, {"x": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        mgr.restore({"x": jnp.zeros((3, 3))})


def test_crash_resume_equivalence(tmp_path):
    """Train 6 steps straight == train 3, 'crash', restore, train 3 more."""
    cfg = _tiny_cfg()
    tcfg = TrainConfig(microbatches=1, remat=False, learning_rate=1e-3)
    loader = Loader(DataConfig(vocab_size=64, seq_len=16, batch_size=4))
    step_fn = jax.jit(S.build_train_step(cfg, tcfg))

    def run(n_start, n_end, state, frozen):
        for i in range(n_start, n_end):
            batch = jax.tree.map(jnp.asarray, loader.batch(i))
            state, _ = step_fn(frozen, state, batch)
        return state

    frozen, adapters, qstate = M.init_params(jax.random.PRNGKey(0), cfg)
    state_a = S.init_train_state(adapters, qstate, tcfg)
    state_a = run(0, 6, state_a, frozen)

    # interrupted run with checkpoint at step 3
    frozen_b, adapters_b, qstate_b = M.init_params(jax.random.PRNGKey(0), cfg)
    state_b = S.init_train_state(adapters_b, qstate_b, tcfg)
    state_b = run(0, 3, state_b, frozen_b)
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(3, state_b)

    # "crash": rebuild from scratch, restore, continue
    frozen_c, adapters_c, qstate_c = M.init_params(jax.random.PRNGKey(0), cfg)
    like = S.init_train_state(adapters_c, qstate_c, tcfg)
    state_c, meta = mgr.restore(like)
    assert meta["step"] == 3
    state_c = run(3, 6, state_c, frozen_c)

    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7),
        state_a.adapters, state_c.adapters)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7),
        state_a.quant, state_c.quant)


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(1, {"x": jnp.ones((128, 128))})
    mgr.wait()
    assert mgr.latest_step() == 1


def test_restore_fingerprint_guard(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    tree = {"x": jnp.zeros((2,))}
    mgr.save(1, tree, {"config_fingerprint": config_fingerprint({"a": 1})})
    got, _ = mgr.restore(tree,
                         expect_fingerprint=config_fingerprint({"a": 1}))
    np.testing.assert_array_equal(np.asarray(got["x"]), np.zeros((2,)))
    with pytest.raises(ValueError, match="fingerprint mismatch"):
        mgr.restore(tree, expect_fingerprint=config_fingerprint({"a": 2}))
    # pre-fingerprint checkpoints restore with a warning, not a failure
    mgr.save(2, tree, {"legacy": True})
    got, meta = mgr.restore(tree, step=2,
                            expect_fingerprint=config_fingerprint({"a": 1}))
    assert meta["legacy"]


# ---------------------------------------------------------------------------
# facade save -> load lifecycle
# ---------------------------------------------------------------------------
def _finetuned_model():
    dcfg = DataConfig(vocab_size=64, seq_len=16, batch_size=4)
    model = api.prepare(ModelConfig(
        name="ckpt-facade", family="dense", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8,
        quant=QuantConfig(mode="fp32"),
        peft=PEFTConfig(method="lora", lora_rank=2)))
    model.calibrate(calibration_batches(dcfg, 2))
    model.convert("quaff")
    tcfg = TrainConfig(microbatches=1, remat=False, learning_rate=1e-3)
    model.finetune(tcfg, Loader(dcfg), steps=3)
    return model, tcfg, dcfg


def test_facade_save_load_bit_identical_eval(tmp_path):
    """calibrate -> convert -> finetune -> save -> load must round-trip the
    quantized base, adapters and momentum scale state to BIT-identical eval
    metrics (the acceptance criterion)."""
    model, _, dcfg = _finetuned_model()
    batch = Loader(dcfg).batch(123)
    before = model.evaluate(batch)
    model.save(str(tmp_path))
    loaded = api.QuaffModel.load(str(tmp_path))
    assert loaded.cfg == model.cfg
    after = loaded.evaluate(batch)
    assert before == after          # float-exact, not allclose
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), model.quant_state, loaded.quant_state)
    out_a = np.asarray(model.generate(batch["tokens"][:, :8], max_new=4))
    out_b = np.asarray(loaded.generate(batch["tokens"][:, :8], max_new=4))
    np.testing.assert_array_equal(out_a, out_b)


def test_facade_load_continues_training(tmp_path):
    """The optimizer moments + step counter ride along: train 3 + save +
    load + train 2 == train 5 straight."""
    model, tcfg, dcfg = _finetuned_model()          # 3 steps in
    model.save(str(tmp_path))
    loaded = api.QuaffModel.load(str(tmp_path))
    more_a = model.finetune(tcfg, Loader(dcfg), steps=2)
    more_b = loaded.finetune(tcfg, Loader(dcfg), steps=2)
    np.testing.assert_allclose(more_a, more_b, rtol=0, atol=0)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), model.adapters, loaded.adapters)


def test_facade_load_refuses_tampered_config(tmp_path):
    model, _, _ = _finetuned_model()
    model.save(str(tmp_path))
    meta_path = os.path.join(
        str(tmp_path), f"step_{3:08d}", "metadata.json")
    with open(meta_path) as f:
        meta = json.load(f)
    meta["config"]["n_heads"] = 2
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="fingerprint mismatch"):
        api.QuaffModel.load(str(tmp_path))


def test_facade_save_before_finetune(tmp_path):
    """A converted-but-untrained model saves/loads too (no optimizer)."""
    dcfg = DataConfig(vocab_size=64, seq_len=16, batch_size=4)
    model = api.prepare(ModelConfig(
        name="ckpt-notrain", family="dense", n_layers=2, d_model=32,
        n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64, head_dim=8,
        quant=QuantConfig(mode="fp32"),
        peft=PEFTConfig(method="lora", lora_rank=2)))
    model.calibrate(calibration_batches(dcfg, 1))
    model.convert("quaff")
    model.save(str(tmp_path))
    loaded = api.QuaffModel.load(str(tmp_path))
    batch = Loader(dcfg).batch(7)
    assert model.evaluate(batch) == loaded.evaluate(batch)
